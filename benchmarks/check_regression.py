"""Bench-regression gate: modeled metrics vs checked-in baselines.

Compares the fresh ``BENCH_*.json`` files at the repo root (written by
``python -m benchmarks.run --quick``) against the quick-mode baselines
checked in under ``benchmarks/baselines/`` and fails (exit 1) if any
MODELED metric regressed more than ``--tolerance`` (default 10%).

What is gated — and what deliberately is not:

  * gated: analytic HBM-traffic / comm-volume metrics, the numbers the
    engine PRs' acceptance criteria are written against.  By key name:
    higher-is-better ``*ratio*`` / ``*reduction*`` / ``*cut*`` fields,
    lower-is-better ``*bytes*`` / ``*words*`` / ``*flip_rate*`` /
    ``*error*`` fields.  Most are pure functions of shapes and the
    traffic model; the flip-rate/error family is the seeded
    reduced-precision parity measurement (``bench_precision``) — drift
    there means the quantization contract changed.  Either way ANY
    drift is a real change: a regression in the engine's
    memory/comm/accuracy contract or an intentional model change — in
    which case refresh the baselines in the same PR (re-run ``--quick``
    and copy the JSONs) so the diff reviews the new numbers.
  * not gated: every wall-clock field (``*_us``, ``*_s``, ``req_per_s``)
    — CI runners are far too noisy — plus shapes, flags and notes.

A baseline key missing from the fresh file also fails: silently dropping
a tracked metric is how regressions hide.  New keys in the fresh file
are fine (benches grow) — but a whole fresh ``BENCH_*.json`` with NO
checked-in baseline fails with a clear message: a new bench must land
its quick-mode baseline in the same PR, or its metrics are never gated.
Malformed or unreadable files (either side) are reported by name, never
as a traceback.

Usage (CI runs the default form after the quick benches):

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--baselines benchmarks/baselines] [--current .] [--tolerance 0.1]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
BASELINES = pathlib.Path(__file__).resolve().parent / "baselines"

HIGHER_BETTER = ("ratio", "reduction", "cut")
LOWER_BETTER = ("bytes", "words", "flip_rate", "error")


def _direction(key: str) -> str | None:
    k = key.lower()
    if any(p in k for p in HIGHER_BETTER):
        return "higher"
    if any(p in k for p in LOWER_BETTER):
        return "lower"
    return None


# fields that identify a benchmark row: list entries carrying any of
# these are addressed by shape, not list position, so quick/full shape
# lists (different lengths/orders at the same indices) line up on the
# rows they share and reordering can never pair unrelated shapes
_ID_KEYS = ("n", "n_users", "N_items", "batch", "d", "K", "K_short",
            "policy", "backend", "scenario")


def _row_label(elem, i: int) -> str:
    if isinstance(elem, dict):
        ids = [f"{k}={elem[k]}" for k in _ID_KEYS if k in elem]
        if ids:
            return "[" + ",".join(ids) + "]"
    return f"[{i}]"


def _walk(obj, path=""):
    """Yield (path, leaf) for every gated numeric leaf."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _walk(v, f"{path}/{k}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _walk(v, path + _row_label(v, i))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        key = path.rsplit("/", 1)[-1]
        if _direction(key) is not None:
            yield path, float(obj)


def _metrics(path: pathlib.Path) -> dict[str, float]:
    """Gated (path -> value) map; duplicate paths are an error — two
    rows collapsing to one label would silently un-gate each other."""
    pairs = list(_walk(json.loads(path.read_text())))
    seen: dict[str, float] = {}
    for p, v in pairs:
        if p in seen:
            raise ValueError(
                f"{path.name}{p}: duplicate metric path — rows share "
                "identical identity fields (fix _ID_KEYS or the bench)")
        seen[p] = v
    return seen


def check_file(baseline_path: pathlib.Path, current_path: pathlib.Path,
               tolerance: float) -> list[str]:
    problems = []
    if not current_path.exists():
        return [f"{current_path.name}: missing (did the bench run?)"]
    try:
        base = _metrics(baseline_path)
        cur = _metrics(current_path)
    except ValueError as e:       # includes JSONDecodeError: name the
        return [str(e)]           # file, don't traceback
    except OSError as e:
        return [f"unreadable bench file: {e}"]
    for path, b in sorted(base.items()):
        if path not in cur:
            # a baseline row the fresh file no longer has IS a failure —
            # silently dropping a tracked metric is how regressions
            # hide.  (Every bench keeps its quick shape list a SUBSET of
            # the full list, so this never fires spuriously on a local
            # full-mode run either.)
            problems.append(
                f"{current_path.name}{path}: gated metric disappeared "
                f"(baseline {b:g})")
            continue
        c = cur[path]
        key = path.rsplit("/", 1)[-1]
        if _direction(key) == "higher":
            bad = c < b * (1.0 - tolerance)
        else:
            bad = c > b * (1.0 + tolerance)
        if bad:
            problems.append(
                f"{current_path.name}{path}: {c:g} vs baseline {b:g} "
                f"({'-' if c < b else '+'}{abs(c / b - 1):.1%}, "
                f"{_direction(key)}-is-better, tol {tolerance:.0%})")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baselines", type=pathlib.Path, default=BASELINES)
    ap.add_argument("--current", type=pathlib.Path, default=ROOT)
    ap.add_argument("--tolerance", type=float, default=0.10)
    args = ap.parse_args(argv)

    baselines = sorted(args.baselines.glob("BENCH_*.json"))
    current = sorted(args.current.glob("BENCH_*.json"))
    if not baselines and not current:
        print(f"no baselines under {args.baselines} and no fresh "
              f"BENCH_*.json under {args.current}", file=sys.stderr)
        return 1
    problems: list[str] = []
    checked = 0
    for bp in baselines:
        file_problems = check_file(bp, args.current / bp.name,
                                   args.tolerance)
        try:
            n = len(list(_walk(json.loads(bp.read_text()))))
        except ValueError:
            n = 0
        problems += file_problems
        checked += n
        status = "FAIL" if file_problems else "ok"
        print(f"{bp.name}: {n} gated metrics — {status}")
    known = {bp.name for bp in baselines}
    for cp in current:
        if cp.name in known:
            continue
        try:
            n_gated = len(_metrics(cp))
        except (ValueError, OSError) as e:
            problems.append(f"{cp.name}: unreadable fresh bench file "
                            f"with no baseline: {e}")
            continue
        if n_gated == 0:      # nothing to gate (wall-clock-only bench)
            print(f"{cp.name}: 0 gated metrics — no baseline needed")
            continue
        problems.append(
            f"{cp.name}: {n_gated} gated metric(s) but no baseline "
            f"checked in under {args.baselines} — a new bench must land "
            "its quick-mode baseline in the same PR (run `python -m "
            "benchmarks.run --quick` and copy the JSON), or its metrics "
            "are never gated")
    if problems:
        print(f"\n{len(problems)} modeled-metric regression(s):",
              file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print(f"all {checked} gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
