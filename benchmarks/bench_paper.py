"""Paper reproduction benchmarks: Tables 3/4/5 + Figs 7/8/9.

Each function reproduces one table/figure of the paper on stat-matched
dataset clones (see repro.data.datasets), at matched interaction counts per
algorithm, and returns a JSON-serializable record.  ``benchmarks.run``
invokes all of them and emits the CSV + results/paper_benchmarks.json that
EXPERIMENTS.md §Reproduction is generated from.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import club, dccb, distclub
from repro.core.types import BanditHyper
from repro.data import datasets

from .common import emit, save_json, timed

# CI-scale interaction budgets per dataset clone (paper counts in Table 1;
# single-core container -> scaled, ratios are the deliverable)
BUDGETS = {
    "movielens": 16_000,
    "lastfm": 12_000,
    "delicious": 12_000,
    "yahoo": 16_000,
    "synthetic-small": 48_000,
}
DCCB_L = 16


def _hyper(spec):
    return BanditHyper(alpha=0.03, beta=2.0, gamma=1.6, sigma=8,
                       max_rounds=16, n_candidates=spec.n_candidates)


def _epochs(spec, T):
    per_epoch = spec.n_users * 2 * 8        # sigma=8, both stages
    return max(1, T // per_epoch)


def run_all_datasets():
    """Tables 3/4/5 + cluster-rate + regret curves in one sweep."""
    rows = {}
    for name, budget in BUDGETS.items():
        spec = datasets.PAPER_DATASETS[name]
        ops, _ = datasets.make_env(spec, seed=1)
        hyper = _hyper(spec)
        key = jax.random.PRNGKey(7)
        n_ep = _epochs(spec, budget)
        dccb_ep = max(1, budget // (spec.n_users * DCCB_L))

        # --- DistCLUB (jit warm-up excluded via repeats on epochs) -------
        t_dc, (st_dc, m_dc, clu_dc) = timed(
            distclub.run, ops, key, hyper, n_ep, spec.d)
        # --- DCCB --------------------------------------------------------
        t_db, (st_db, m_db, clu_db) = timed(
            dccb.run, ops, key, hyper, dccb_ep, spec.d, DCCB_L)
        # --- CLUB (sequential; matched budget would take hours on one
        #     core — run a fixed slice and report per-interaction time) ---
        t_cl_T = min(2048, budget)
        t_cl, (st_cl, m_cl) = timed(
            club.run, ops, key, hyper, t_cl_T, spec.d)

        T_dc = int(m_dc.interactions.sum())
        T_db = int(m_db.interactions.sum())

        def ratio(m):
            return float(m.reward.sum()) / max(float(m.rand_reward.sum()), 1e-9)

        rows[name] = {
            "interactions": {"distclub": T_dc, "dccb": T_db, "club": t_cl_T},
            # per-interaction wall time (ratios = Table 3 analogue)
            "us_per_interaction": {
                "distclub": 1e6 * t_dc / T_dc,
                "dccb": 1e6 * t_db / T_db,
                "club": 1e6 * t_cl / t_cl_T,
            },
            # Table 4 analogue: bytes shipped per interaction
            "comm_bytes_per_interaction": {
                "distclub": float(st_dc.comm_bytes) / T_dc,
                "dccb": float(st_db.comm_bytes) / T_db,
            },
            # Table 5 / Fig 8 analogue: reward normalized by random policy
            "reward_over_random": {
                "distclub": ratio(m_dc),
                "dccb": ratio(m_db),
                "club": ratio(m_cl),
            },
            # Fig 9: cumulative regret per interaction (lower better)
            "regret_per_interaction": {
                "distclub": float(m_dc.regret.sum()) / T_dc,
                "dccb": float(m_db.regret.sum()) / T_db,
                "club": float(m_cl.regret.sum()) / t_cl_T,
            },
            # Fig 7: cluster count after each stage-2 / gossip round
            "cluster_curve": {
                "distclub": np.asarray(clu_dc).tolist(),
                "dccb": np.asarray(clu_db).tolist(),
            },
        }
        r = rows[name]
        emit(f"table3_speed_{name}_distclub",
             r["us_per_interaction"]["distclub"],
             f"dccb={r['us_per_interaction']['dccb']:.1f};"
             f"club={r['us_per_interaction']['club']:.1f}")
        emit(f"table4_comm_{name}",
             r["comm_bytes_per_interaction"]["distclub"],
             f"dccb={r['comm_bytes_per_interaction']['dccb']:.1f}")
        emit(f"table5_reward_{name}",
             1e6 * r["reward_over_random"]["distclub"],
             f"dccb={r['reward_over_random']['dccb']:.3f};"
             f"club={r['reward_over_random']['club']:.3f}")

    # paper-parameter analytic Table 4 (full interaction counts, L=5000):
    analytic = {}
    for name, spec in datasets.PAPER_DATASETS.items():
        if name.startswith("synthetic-"):
            continue
        T, n, d = spec.n_interactions, spec.n_users, spec.d
        L = 5000
        rounds_dccb = max(1, T // (n * L)) if T > n else 1
        # every user pulls buffer+active per gossip round
        dccb_bytes = max(rounds_dccb, 1) * n * (L + 1) * (d * d + d) * 4
        # DistCLUB: stage-2 every ~2*sigma rounds/user with sigma=2500
        stages = max(1, T // (n * 2 * 2500))
        dclub_bytes = stages * distclub.stage2_comm_bytes(n, d)
        analytic[name] = {"dccb_GB": dccb_bytes / 1e9,
                          "distclub_MB": dclub_bytes / 1e6}
    return {"measured": rows, "table4_paper_scale_analytic": analytic}


def main():
    out = run_all_datasets()
    save_json("paper_benchmarks", out)

    # headline geo-means (paper: 8.87x speedup, 14.5% reward gain).
    # Wall-clock on this single core only sees the compute-side difference;
    # the paper's speedup is dominated by NETWORK time, so we also report a
    # modeled cluster step time = measured compute + comm_bytes / 10 Gbps
    # (the paper's EC2 fabric, 1.25 GB/s) — the apples-to-apples analogue.
    import math
    NET = 1.25e9
    speed, modeled, reward = [], [], []
    for name, r in out["measured"].items():
        speed.append(r["us_per_interaction"]["dccb"]
                     / r["us_per_interaction"]["distclub"])
        t_dc = (r["us_per_interaction"]["distclub"] / 1e6
                + r["comm_bytes_per_interaction"]["distclub"] / NET)
        t_db = (r["us_per_interaction"]["dccb"] / 1e6
                + r["comm_bytes_per_interaction"]["dccb"] / NET)
        # paper buffer length is 5000, not the CI-scale 16: scale the DCCB
        # comm term accordingly for the paper-parameter model
        t_db_paper = (r["us_per_interaction"]["dccb"] / 1e6
                      + r["comm_bytes_per_interaction"]["dccb"]
                      * (5001 / (DCCB_L + 1)) / NET)
        modeled.append(t_db_paper / t_dc)
        reward.append(r["reward_over_random"]["distclub"]
                      / max(r["reward_over_random"]["dccb"], 1e-9))
    gm = lambda xs: math.exp(sum(math.log(max(x, 1e-9)) for x in xs) / len(xs))
    emit("headline_speedup_vs_dccb_compute_only", gm(speed) * 1e6,
         f"single-core wall clock, ours={gm(speed):.2f}x")
    emit("headline_speedup_vs_dccb_modeled_10gbps", gm(modeled) * 1e6,
         f"paper=8.87x geo-mean, ours={gm(modeled):.2f}x (L=5000)")
    emit("headline_reward_vs_dccb", gm(reward) * 1e6,
         f"paper=+14.5%, ours={100 * (gm(reward) - 1):.1f}%")
    out["headline"] = {
        "speedup_compute_only": gm(speed),
        "speedup_modeled_10gbps_L5000": gm(modeled),
        "reward_gain": gm(reward) - 1,
    }
    save_json("paper_benchmarks", out)
    return out


if __name__ == "__main__":
    main()
