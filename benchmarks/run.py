"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; writes results/*.json consumed by
EXPERIMENTS.md plus BENCH_interact.json at the repo root (the fused-engine
perf trajectory, tracked from PR 1 onward).

``--quick`` runs only the fused-interaction microbenchmark at reduced
shapes/repeats — finishes in well under 2 minutes on one CPU core — and
still emits BENCH_interact.json, so CI can track the hot-path trend cheaply.
"""
from __future__ import annotations

import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fused-interaction bench only, small shapes, "
                         "<2 min on one CPU core")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    from . import bench_interact
    if args.quick:
        bench_interact.main(quick=True)
        return
    bench_interact.main()
    from . import bench_kernels
    bench_kernels.main()
    from . import bench_paper
    bench_paper.main()
    from . import bench_scaling
    bench_scaling.main()


if __name__ == "__main__":
    main()
