"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; writes results/*.json consumed by
EXPERIMENTS.md plus BENCH_interact.json / BENCH_graph.json /
BENCH_drift.json / BENCH_serve.json / BENCH_retrieval.json /
BENCH_faults.json / BENCH_churn.json / BENCH_experiment.json /
BENCH_precision.json at the repo root (the engine perf trajectories,
tracked per PR).

``--quick`` runs the fused-interaction microbenchmark at reduced
shapes/repeats, the stage-2 graph bench (full n sweep — its acceptance
gates live at n=16k/64k — with trimmed repeats), the non-stationary
drift scenario through the unified engine (single-host + 8-device
sharded), the online-serving transaction bench, the catalog-scale
retrieval bench (streaming top-K incl. the 2**20-item reference row +
8-device item-sharded transaction), the seeded fault-injection
bench (delayed/lossy feedback vs its clean control), the catalog
churn bench (double-buffered swaps under live traffic vs the churn-free
control), and the online-experimentation bench (Thompson-sampling
meta-selector vs the best fixed arm + routing overhead vs a bare
session), and the reduced-precision parity bench (modeled HBM cuts +
choice-flip rate vs the f32 oracle); a few minutes on one CPU core, and
still emits every BENCH_*.json, so CI can track the hot-path trends
cheaply and gate the modeled metrics (``benchmarks.check_regression``).

Failure policy: every sub-benchmark runs even if an earlier one fails,
but any failure makes the harness exit non-zero and name the culprits —
CI's quick-bench step is a real gate, not best-effort.  Each
sub-benchmark also runs under a wall-clock timeout (``--bench-timeout``,
SIGALRM-based, so a hung jax compile or subprocess counts as a failure
instead of wedging CI; no-op on platforms without SIGALRM).
"""
from __future__ import annotations

import argparse
import importlib
import signal
import sys
import traceback


def _bench_list(quick: bool):
    # each module is imported lazily INSIDE its runner so an import-time
    # error in one bench is reported/isolated like any other failure —
    # the remaining benches still run
    def runner(mod: str, **kw):
        def call():
            m = importlib.import_module(f".{mod}", __package__)
            return m.main(**kw)
        return call

    names = ["bench_interact", "bench_graph", "bench_drift", "bench_serve",
             "bench_retrieval", "bench_faults", "bench_churn",
             "bench_experiment", "bench_precision"]
    benches = [(n, runner(n, quick=quick)) for n in names]
    if not quick:
        benches += [(n, runner(n)) for n in
                    ("bench_kernels", "bench_paper", "bench_scaling")]
    return benches


def _call_with_timeout(fn, seconds: int):
    """Run ``fn()`` under a SIGALRM deadline (main thread only; silently
    unenforced where SIGALRM doesn't exist, e.g. Windows)."""
    if seconds <= 0 or not hasattr(signal, "SIGALRM"):
        return fn()

    def _expired(signum, frame):
        raise TimeoutError(f"benchmark exceeded --bench-timeout={seconds}s")

    prev = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(seconds)
    try:
        return fn()
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="engine benches only (interact/graph/drift/serve/"
                         "retrieval/faults/churn/experiment/precision), "
                         "reduced shapes/repeats, a few minutes on one "
                         "CPU core")
    ap.add_argument("--bench-timeout", type=int, default=1800,
                    help="per-sub-benchmark wall-clock limit in seconds "
                         "(0 disables); a timeout is reported like any "
                         "other bench failure")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    failures: list[str] = []
    for name, fn in _bench_list(args.quick):
        try:
            _call_with_timeout(fn, args.bench_timeout)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"FAILED benchmarks: {', '.join(failures)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
