"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; writes results/*.json consumed by
EXPERIMENTS.md.
"""
from __future__ import annotations


def main() -> None:
    print("name,us_per_call,derived")
    from . import bench_kernels
    bench_kernels.main()
    from . import bench_paper
    bench_paper.main()
    from . import bench_scaling
    bench_scaling.main()


if __name__ == "__main__":
    main()
