"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; writes results/*.json consumed by
EXPERIMENTS.md plus BENCH_interact.json / BENCH_graph.json at the repo root
(the fused-engine and stage-2 graph-engine perf trajectories, tracked from
PR 1 / PR 2 onward).

``--quick`` runs the fused-interaction microbenchmark at reduced
shapes/repeats, the stage-2 graph bench (full n sweep — its acceptance
gates live at n=16k/64k — with trimmed repeats), the non-stationary
drift scenario through the unified engine (single-host + 8-device
sharded), and the online-serving transaction bench (fused vs reference,
single-host + sharded); a few minutes on one CPU core, and still emits
every BENCH_*.json, so CI can track the hot-path trends cheaply.
"""
from __future__ import annotations

import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fused-interaction + graph + serve benches only, "
                         "reduced shapes/repeats, a few minutes on one "
                         "CPU core")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    from . import bench_drift, bench_graph, bench_interact, bench_serve
    if args.quick:
        bench_interact.main(quick=True)
        bench_graph.main(quick=True)
        bench_drift.main(quick=True)
        bench_serve.main(quick=True)
        return
    bench_interact.main()
    bench_graph.main()
    bench_drift.main()
    bench_serve.main()
    from . import bench_kernels
    bench_kernels.main()
    from . import bench_paper
    bench_paper.main()
    from . import bench_scaling
    bench_scaling.main()


if __name__ == "__main__":
    main()
