"""Fig 6 analogue: scaling of the sharded DistCLUB runtime with device count.

True multi-node scaling can't be measured on one CPU core; we still verify
the *runtime mechanics* scale (same program, 1..8 host devices, fixed
problem) and report the collective-volume model per device count — the
quantity that determines scaling on a real interconnect (DistCLUB's stage-2
bytes/device FALL with device count; DCCB's gossip bytes/device do not:
that is precisely the paper's Fig 6 divergence).
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

from .common import emit, save_json

REPO = pathlib.Path(__file__).resolve().parents[1]

_CODE = r"""
import time, jax, jax.numpy as jnp
from repro.distributed import distclub_shard
from repro.core.types import BanditHyper

n_dev = len(jax.devices())
mesh = jax.make_mesh((n_dev,), ("users",))
hyper = BanditHyper(sigma=8, max_rounds=16, gamma=1.6, n_candidates=20)
init_fn, epoch = distclub_shard.make_runtime(mesh, ("users",), n=2048, d=25,
                                             hyper=hyper)
state = init_fn(jax.random.PRNGKey(0))
state, m, _ = epoch(state, jax.random.PRNGKey(1))   # compile + warm
jax.block_until_ready(state)
t0 = time.perf_counter()
for i in range(3):
    state, m, _ = epoch(state, jax.random.PRNGKey(i + 2))
jax.block_until_ready(state)
print("EPOCH_S", (time.perf_counter() - t0) / 3)
"""


def main():
    rows = {}
    for n in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = str(REPO / "src")
        out = subprocess.run([sys.executable, "-c", _CODE],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        if out.returncode != 0:
            print(out.stderr[-2000:])
            continue
        t = float(out.stdout.split("EPOCH_S")[1].split()[0])
        # analytic per-device comm for the paper's production scale
        n_users, d = 20_480, 25
        dclub_per_dev = 2 * (n_users // n) * (d * d + d) * 4
        dccb_per_dev = (n_users // n) * (5000 + 1) * (d * d + d) * 4
        rows[n] = {"epoch_s": t,
                   "distclub_stage2_bytes_per_dev": dclub_per_dev,
                   "dccb_gossip_bytes_per_dev": dccb_per_dev}
        emit(f"fig6_scaling_dev{n}", 1e6 * t,
             f"comm/dev: distclub={dclub_per_dev/1e6:.1f}MB "
             f"dccb={dccb_per_dev/1e9:.1f}GB")
    save_json("scaling", rows)
    return rows


if __name__ == "__main__":
    main()
