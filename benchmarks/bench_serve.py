"""Online-serving transaction benchmark + per-request HBM accounting.

Times the full jit-compiled `OnlineBandit.step` transaction (score ->
fused choose -> reward -> duplicate-safe fold -> refresh cond) for the
distclub policy at serving shapes, two engines:

  reference   the jnp engine (`REPRO_BACKEND=reference`)
  fused       the interaction-engine kernels; off-TPU this is explicitly
              the interpret-mode Pallas backend (kernel-path validation,
              NOT a wall-clock claim — see bench_interact's rationale),
              flagged per record via `fused_backend`/`wallclock_comparable`.

The per-request HBM model extends bench_interact's per-round model
(serving is M-free, like the sharded runtime) with the serving layer's
extra row traffic: the beta-heuristic gathers of the frozen cluster
snapshot (`uMcinv` d^2 + `ubc` d + `umean_occ` 1 words) plus the
scatter-back of the updated `Minv`/`b` rows already counted by the
update sweep.  The refresh itself amortizes over `refresh_every`
requests and is excluded (stage-2's model lives in bench_graph).

Also records an 8-device sharded serving row (subprocess host-platform
mesh): the same transaction under shard_map, reference engine.

Writes BENCH_serve.json at the repo root (tracked from PR 4 onward).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro import serve
from repro.core import env, env_ops
from repro.core.types import BanditHyper

from .bench_interact import hbm_words_fused, hbm_words_reference
from .common import emit, timed

ROOT = pathlib.Path(__file__).resolve().parents[1]

# (n_users, batch) at the serving feature/candidate shape d=32, K=64.
# QUICK_SHAPES stays a subset of FULL_SHAPES: check_regression matches
# rows by shape identity and treats a vanished baseline row as a failure,
# so a full-mode run must cover every quick-mode (baseline) row.
FULL_SHAPES = [(1024, 256), (4096, 256), (16384, 512)]
QUICK_SHAPES = [(1024, 256)]
D, K = 32, 64

_SHARDED_CODE = r"""
import time, jax, jax.numpy as jnp
from repro import serve
from repro.core import env, env_ops
from repro.core.types import BanditHyper

N, B, D, K = {n}, {batch}, 32, 64
hyper = BanditHyper(alpha=0.05, gamma=1.5, n_candidates=K)
e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N, D, 8, K)
ops = env_ops.synthetic_ops(e)
theta = e.theta

def reward_fn(key, uids, ctx, choice):
    return env.step_rewards(key, theta[uids], ctx, choice)

mesh = jax.make_mesh((8,), ("users",))
session = serve.OnlineBandit.sharded(mesh, N, D, hyper, policy="distclub",
                                     refresh_every=0, backend="reference")
ctx = jax.random.normal(jax.random.PRNGKey(1), (B, K, D))
ctx = ctx / jnp.linalg.norm(ctx, axis=-1, keepdims=True)
uids = jax.random.permutation(jax.random.PRNGKey(2), N)[:B].astype(jnp.int32)
session, c, m = serve.step(session, jax.random.PRNGKey(3), uids, ctx,
                           reward_fn)               # compile + warm
jax.block_until_ready(c)
t0 = time.perf_counter()
REP = 5
for i in range(REP):
    session, c, m = serve.step(session, jax.random.PRNGKey(4 + i), uids,
                               ctx, reward_fn)
jax.block_until_ready(c)
print("SHARD_STEP_US", 1e6 * (time.perf_counter() - t0) / REP)
"""


def serve_words(d: int, K: int, fused: bool) -> int:
    """f32 words of HBM traffic per request (M-free engine + the
    clustered policy's frozen-snapshot gathers)."""
    base = (hbm_words_fused if fused else hbm_words_reference)(
        d, K, with_M=False)
    snapshot = d * d + d + 1            # uMcinv, ubc, umean_occ rows
    return base + snapshot


def _session(n, kind, interpret):
    hyper = BanditHyper(alpha=0.05, gamma=1.5, n_candidates=K)
    return serve.OnlineBandit.create(n, D, hyper, policy="distclub",
                                     refresh_every=0, backend=kind,
                                     interpret=interpret)


def bench_shape(n, batch, repeats=3):
    e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), n, D, 8, K)
    theta = e.theta

    def reward_fn(key, uids, ctx, choice):
        return env.step_rewards(key, theta[uids], ctx, choice)

    ctx = jax.random.normal(jax.random.PRNGKey(1), (batch, K, D))
    ctx = ctx / jnp.linalg.norm(ctx, axis=-1, keepdims=True)
    uids = jax.random.permutation(
        jax.random.PRNGKey(2), n)[:batch].astype(jnp.int32)
    key = jax.random.PRNGKey(3)

    on_tpu = jax.default_backend() == "tpu"
    results = {}
    for col, (kind, interp, reps) in {
        "reference": ("reference", None, repeats),
        # like bench_interact: off-TPU the fused column must exercise the
        # kernel path (interpret mode), never silently fall back
        "fused": ("pallas", None if on_tpu else True,
                  repeats if on_tpu else 1),
    }.items():
        sess = _session(n, kind, interp)
        sess, c, _ = serve.step(sess, key, uids, ctx, reward_fn)  # compile
        jax.block_until_ready(c)

        def one_step(sess=sess):
            s2, c2, _ = serve.step(sess, key, uids, ctx, reward_fn)
            return c2

        secs, _ = timed(one_step, repeats=reps)
        results[col] = 1e6 * secs

    rec = {
        "n_users": n, "batch": batch, "d": D, "K": K,
        "policy": "distclub",
        "fused_backend": "pallas" if on_tpu else "pallas_interpret",
        "wallclock_comparable": on_tpu,
        "reference_us": results["reference"],
        "fused_us": results["fused"],
        "reference_req_per_s": batch / (results["reference"] * 1e-6),
        "hbm_bytes_per_request_reference": 4 * serve_words(D, K, False),
        "hbm_bytes_per_request_fused": 4 * serve_words(D, K, True),
        "hbm_traffic_ratio": serve_words(D, K, False)
        / serve_words(D, K, True),
    }
    emit(f"serve_step_n{n}_B{batch}_reference", rec["reference_us"],
         f"req/s={rec['reference_req_per_s']:.0f}")
    emit(f"serve_step_n{n}_B{batch}_fused", rec["fused_us"],
         f"hbm_ratio={rec['hbm_traffic_ratio']:.2f}x")
    return rec


def _sharded_row(n, batch):
    envv = dict(os.environ)
    envv["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    envv["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_CODE.format(n=n, batch=batch)],
        capture_output=True, text=True, env=envv, timeout=900)
    if out.returncode != 0 or "SHARD_STEP_US" not in out.stdout:
        return {"error": (out.stderr or out.stdout)[-800:]}
    us = float(out.stdout.split("SHARD_STEP_US")[1].split()[0])
    emit(f"serve_step_sharded8_n{n}_B{batch}", us,
         f"req/s={batch / (us * 1e-6):.0f}")
    return {"n_users": n, "batch": batch, "step_us": us,
            "req_per_s": batch / (us * 1e-6)}


def main(quick: bool = False):
    shapes = QUICK_SHAPES if quick else FULL_SHAPES
    records = [bench_shape(n, b, repeats=2 if quick else 3)
               for (n, b) in shapes]
    payload = {
        "mode": "quick" if quick else "full",
        "jax_backend": jax.default_backend(),
        "fused_wallclock_note": (
            "fused_us is a compiled TPU kernel only where "
            "wallclock_comparable is true; on CPU runners it is the "
            "Pallas interpreter (kernel-path validation, not a speed "
            "claim)"),
        "hbm_model_note": (
            "per-request words: bench_interact per-round model with "
            "with_M=False (serving is M-free) + d^2+d+1 frozen-snapshot "
            "gathers; refresh amortizes over refresh_every and is "
            "modeled in bench_graph"),
        "shapes": records,
        "sharded_8dev": _sharded_row(*shapes[0]),
        "min_traffic_ratio": min(r["hbm_traffic_ratio"] for r in records),
    }
    (ROOT / "BENCH_serve.json").write_text(json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    main()
