"""Fault-injection benchmark: the feedback loop under hostile delivery.

Runs the seeded harness (`serve.faults.run_faulted`) over a small grid
of fault scenarios against a clean control on IDENTICAL traffic (same
JAX keys; the fault draws come from a separate NumPy stream) and
records, per scenario:

  matched_ratio           folded / issued decisions — deterministic
                          bookkeeping of the pending ring (gated)
  reward_vs_clean_ratio   true realized reward vs the clean control —
                          the learning cost of the faults (gated; fully
                          seeded, so any drift is a real change in the
                          fold/ring semantics)
  regret_degradation      faulted regret / clean regret (recorded, not
                          gated: a ratio of two small sums, noisier
                          than its inputs)
  tx_per_s                wall clock — never gated

Writes BENCH_faults.json at the repo root (tracked from PR 6 onward).
"""
from __future__ import annotations

import json
import pathlib

import jax

from repro import serve
from repro.core import env
from repro.core.types import BanditHyper
from repro.serve import faults

from .common import emit

ROOT = pathlib.Path(__file__).resolve().parents[1]

N_USERS, D, K, BATCH = 64, 8, 10, 16
ROUNDS, CAPACITY, TTL = 30, 256, 16

# QUICK_SCENARIOS stays a subset of FULL_SCENARIOS (check_regression
# matches rows by identity and fails on vanished baseline rows)
FULL_SCENARIOS = [
    ("clean", faults.FaultSpec()),
    ("delay_loss_dup", faults.FaultSpec(seed=7, p_delay=0.3, max_delay=4,
                                        p_loss=0.1, p_dup=0.05)),
    ("stall", faults.FaultSpec(seed=3, stall_every=5, stall_rounds=2)),
    ("heavy", faults.FaultSpec(seed=9, p_delay=0.5, max_delay=6,
                               p_loss=0.2, p_dup=0.1)),
]
QUICK_SCENARIOS = FULL_SCENARIOS[:2]


def _session():
    hyper = BanditHyper(sigma=4, max_rounds=1, gamma=1.5, n_candidates=K)
    return serve.OnlineBandit.create(
        N_USERS, D, hyper, policy="distclub", refresh_every=N_USERS,
        pending_capacity=CAPACITY, pending_ttl=TTL)


def main(quick: bool = False):
    scenarios = QUICK_SCENARIOS if quick else FULL_SCENARIOS
    e, _ = env.make_synthetic_env(jax.random.PRNGKey(0), N_USERS, D, 4, K)

    _, clean = faults.run_faulted(_session(), e.theta, ROUNDS,
                                  faults.FaultSpec(), batch=BATCH, key=11)
    rows = []
    for name, spec in scenarios:
        _, rep = faults.run_faulted(_session(), e.theta, ROUNDS, spec,
                                    batch=BATCH, key=11)
        st = rep.pending
        row = {
            "scenario": name, "policy": "distclub",
            "n_users": N_USERS, "batch": BATCH, "d": D, "K": K,
            "rounds": ROUNDS, "capacity": CAPACITY, "ttl": TTL,
            "p_delay": spec.p_delay, "p_loss": spec.p_loss,
            "p_dup": spec.p_dup, "stall_every": spec.stall_every,
            "matched_ratio": st["matched"] / max(1, st["issued"]),
            "reward_vs_clean_ratio": rep.reward / max(clean.reward, 1e-9),
            "regret_degradation": rep.regret / max(clean.regret, 1e-9),
            "delivered": rep.delivered,
            "unmatched": st["unmatched"], "expired": st["expired"],
            "dropped": st["dropped"],
            "tx_per_s": rep.tx_per_s,
        }
        rows.append(row)
        emit(f"faults_{name}", 1e6 / max(rep.tx_per_s, 1e-9),
             f"matched={row['matched_ratio']:.3f} "
             f"reward_vs_clean={row['reward_vs_clean_ratio']:.3f} "
             f"regret_x={row['regret_degradation']:.2f}")

    payload = {
        "mode": "quick" if quick else "full",
        "jax_backend": jax.default_backend(),
        "determinism_note": (
            "matched_ratio and reward_vs_clean_ratio are fully seeded "
            "(JAX traffic keys + NumPy fault stream) — gated; "
            "regret_degradation is recorded but not gated (ratio of "
            "small sums); tx_per_s is wall clock, never gated"),
        "scenarios": rows,
    }
    (ROOT / "BENCH_faults.json").write_text(json.dumps(payload, indent=1))
    return payload


if __name__ == "__main__":
    main()
